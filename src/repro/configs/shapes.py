"""Assigned input-shape set for the LM-family architectures.

Every (arch x shape) cell is well-defined; applicability rules:
  * decode_* / long_* lower `serve_step` (1 new token + KV cache of seq_len)
  * long_500k runs only for sub-quadratic archs (ssm / hybrid / SWA-moe)
  * encoder frames / image patches are stubbed embeddings via input_specs()
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.models.common import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# chip count the global batches above assume (the single-pod production
# mesh); smaller worlds scale proportionally via shape_for_chips
PRODUCTION_CHIPS = 256


def shape_for_chips(shape: ShapeSpec, chips: int) -> ShapeSpec:
    """Scale a shape's global batch to a sub-mesh run (elastic world
    sizes, DESIGN.md §13): the per-chip batch is the invariant, so an
    in-process run on fewer devices keeps the same local shapes."""
    if chips >= PRODUCTION_CHIPS:
        return shape
    gb = max(1, shape.global_batch * chips // PRODUCTION_CHIPS)
    return ShapeSpec(shape.name, shape.seq_len, gb, shape.kind)

# archs for which long_500k is runnable (sub-quadratic decode state)
LONG_OK_FAMILIES = ("ssm", "hybrid")


def long_ok(cfg: ModelConfig) -> bool:
    if cfg.family in LONG_OK_FAMILIES:
        return True
    return cfg.sliding_window > 0          # SWA bounds the live KV window


def cell_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """-> (applicable, reason-if-not)."""
    if shape.name == "long_500k" and not long_ok(cfg):
        return False, "full quadratic attention; long_500k skipped (DESIGN.md)"
    return True, ""


def train_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jnp.zeros((B, S), jnp.int32),   # ShapeDtypeStruct at callsite
        "labels": jnp.zeros((B, S), jnp.int32),
    }
    return specs
