"""paligemma-3b — VLM: SigLIP frontend STUBBED (precomputed patch embeddings),
Gemma-style MQA decoder backbone [arXiv:2407.07726; hf]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    num_patches=256,           # stub image patch prefix
    mlp_type="gelu",
    norm_type="rmsnorm",
)
