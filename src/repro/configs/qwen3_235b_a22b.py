"""qwen3-235b-a22b — the paper's own evaluation model (94L, 64Q/4KV heads,
128 experts top-8) [arXiv:2505.09388]. Used to mirror the paper's numbers."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    num_experts=128,
    num_shared_experts=0,
    top_k=8,
    d_expert=1536,
    qk_norm=True,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    rope_theta=1e6,
)
