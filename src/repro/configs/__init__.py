"""Architecture config registry: the 10 assigned archs + the paper's model."""
from __future__ import annotations

from repro.models.common import ModelConfig

from repro.configs.internlm2_1_8b import CONFIG as _internlm2
from repro.configs.starcoder2_15b import CONFIG as _starcoder2
from repro.configs.qwen3_4b import CONFIG as _qwen3_4b
from repro.configs.mistral_large_123b import CONFIG as _mistral_large
from repro.configs.qwen2_moe_a2_7b import CONFIG as _qwen2_moe
from repro.configs.mixtral_8x7b import CONFIG as _mixtral
from repro.configs.whisper_base import CONFIG as _whisper
from repro.configs.mamba2_780m import CONFIG as _mamba2
from repro.configs.zamba2_2_7b import CONFIG as _zamba2
from repro.configs.paligemma_3b import CONFIG as _paligemma
from repro.configs.qwen3_235b_a22b import CONFIG as _qwen3_235b

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        _internlm2, _starcoder2, _qwen3_4b, _mistral_large, _qwen2_moe,
        _mixtral, _whisper, _mamba2, _zamba2, _paligemma, _qwen3_235b,
    ]
}

ASSIGNED = [
    "internlm2-1.8b", "starcoder2-15b", "qwen3-4b", "mistral-large-123b",
    "qwen2-moe-a2.7b", "mixtral-8x7b", "whisper-base", "mamba2-780m",
    "zamba2-2.7b", "paligemma-3b",
]


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]
