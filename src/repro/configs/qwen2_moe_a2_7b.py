"""qwen2-moe-a2.7b — MoE: 60 routed top-4 + 4 shared experts
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]. Full Moebius technique applies."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=5632,                 # shared-expert aggregate intermediate
    vocab_size=151936,
    num_experts=60,
    num_shared_experts=4,
    top_k=4,
    d_expert=1408,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    rope_theta=1e6,
)
