"""starcoder2-15b — dense GQA LM, RoPE, GELU MLP, LayerNorm [arXiv:2402.19173; hf]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    mlp_type="gelu",
    norm_type="layernorm",
    rope_theta=1e5,
)
