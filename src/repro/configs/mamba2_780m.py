"""mamba2-780m — attention-free SSD (state-space duality)
[arXiv:2405.21060; unverified]. long_500k runs (O(1) state)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    ssm_conv=4,
    ssm_groups=1,
    norm_type="rmsnorm",
)
