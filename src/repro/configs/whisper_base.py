"""whisper-base — encoder-decoder; conv frontend STUBBED (precomputed frame
embeddings) [arXiv:2212.04356; unverified]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    num_layers=6,              # decoder layers
    encoder_layers=6,
    encoder_seq=1500,          # stub frame positions
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    mlp_type="gelu",
    norm_type="layernorm",
    tie_embeddings=True,
)
