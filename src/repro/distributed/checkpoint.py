"""Layout-agnostic sharded checkpointing with reshard-on-restore.

Canonical on-disk format is the GLOBAL logical form (experts unpacked to
(L, E, 2I, D), vocab unpadded): a checkpoint written from either layout or
any mesh restores into any layout on any compatible mesh — restart after a
node failure, elastic rescale, and EP<->TP flips all reuse the same path
(the switch machinery generalized to the persistence plane).

Format: <dir>/manifest.json + one .npy per leaf (chunked by first axis for
large leaves so per-file size stays bounded — the per-host shard-file
pattern at scale). Async save via a background thread.
"""
from __future__ import annotations

import json
import os
import threading
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layouts import padded_vocab
from repro.models.common import ModelConfig
from repro.models.moe import (make_expert_layout, pack_w13, pack_experts,
                              unpack_experts, unpack_w13)

_CHUNK_BYTES = 256 * 1024 * 1024


def _leaf_paths(tree, prefix=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _leaf_paths(tree[k], prefix + (k,))
    else:
        yield prefix, tree


def _set_path(tree, path, val):
    for k in path[:-1]:
        tree = tree.setdefault(k, {})
    tree[path[-1]] = val


def to_canonical(cfg: ModelConfig, params: dict, layout: str, G: int) -> dict:
    """Stored layout params -> global logical form (host numpy)."""
    out = jax.tree.map(lambda x: np.asarray(x), params)
    V, Vp = cfg.vocab_size, padded_vocab(cfg.vocab_size)
    for k in ("embed", "lm_head"):
        if k in out and out[k].shape[0] == Vp:
            out[k] = out[k][:V]
    if cfg.is_moe and "layers" in out and "moe" in out["layers"]:
        lay = make_expert_layout(cfg.num_experts, G, layout)
        moe = dict(out["layers"]["moe"])
        E = cfg.num_experts
        moe["w13"] = np.asarray(jax.vmap(
            lambda w: unpack_w13(w, lay, E))(jnp.asarray(moe["w13"])))
        moe["w2"] = np.asarray(jax.vmap(
            lambda w: unpack_experts(w, lay, 2, E))(jnp.asarray(moe["w2"])))
        out["layers"] = dict(out["layers"])
        out["layers"]["moe"] = moe
    return out


def from_canonical(cfg: ModelConfig, canon: dict, layout: str, G: int) -> dict:
    """Global logical form -> stored layout params (host numpy/jnp)."""
    from repro.core.layouts import pack_params
    return pack_params(cfg, jax.tree.map(jnp.asarray, canon), layout, G)


def save_checkpoint(path: str, cfg: ModelConfig, params: dict, layout: str,
                    G: int, *, opt_state=None, step: int = 0,
                    async_save: bool = False):
    def _do():
        p = Path(path)
        p.mkdir(parents=True, exist_ok=True)
        canon = to_canonical(cfg, params, layout, G)
        manifest = {"step": step, "arch": cfg.name, "leaves": []}
        trees = {"params": canon}
        if opt_state is not None:
            trees["opt"] = jax.tree.map(np.asarray, opt_state)
        for tname, tree in trees.items():
            for lp, leaf in _leaf_paths(tree):
                name = tname + "." + ".".join(lp) if lp else tname
                arr = np.asarray(leaf)
                nchunk = max(1, -(-arr.nbytes // _CHUNK_BYTES))
                nchunk = min(nchunk, max(1, arr.shape[0] if arr.ndim else 1))
                files = []
                for ci, piece in enumerate(np.array_split(arr, nchunk)
                                           if arr.ndim else [arr]):
                    fn = f"{name}.{ci}.npy"
                    np.save(p / fn, piece)
                    files.append(fn)
                manifest["leaves"].append(
                    {"tree": tname, "path": list(lp), "files": files,
                     "shape": list(arr.shape), "dtype": str(arr.dtype)})
        (p / "manifest.json").write_text(json.dumps(manifest))

    if async_save:
        t = threading.Thread(target=_do, daemon=True)
        t.start()
        return t
    _do()
    return None


def restore_checkpoint(path: str, cfg: ModelConfig, layout: str, G: int,
                       *, mesh=None, shardings=None, with_opt: bool = False):
    """Restore into `layout` at group size G; device_put with `shardings`
    (a params-sharding pytree) when given. Returns (params, opt, step)."""
    p = Path(path)
    manifest = json.loads((p / "manifest.json").read_text())
    trees: dict = {"params": {}, "opt": {}}
    for leaf in manifest["leaves"]:
        parts = [np.load(p / f) for f in leaf["files"]]
        arr = parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)
        _set_path(trees[leaf["tree"]], tuple(leaf["path"]), arr)
    params = from_canonical(cfg, trees["params"], layout, G)
    if shardings is not None:
        params = jax.tree.map(jax.device_put, params, shardings)
    opt = trees["opt"] if (with_opt and trees["opt"]) else None
    return params, opt, manifest["step"]
