"""Elastic scaling + failure recovery.

Training: re-mesh via the layout-agnostic checkpoint (shrink/grow the data
axis, or change the model-group size where divisibility allows) — restore
reshards automatically because the on-disk form is global-logical.

Serving: a lost rank's KV is host-recoverable metadata + re-prefill: the
affected requests' prompts are extended by their generated tokens (teacher-
forced) and re-enter the prefill queue; no other rank's state is touched.
The TP->EP greedy partitioner doubles as the rebalancing step afterwards.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.serving.request import State


@dataclass(frozen=True)
class RescalePlan:
    old_shape: dict
    new_shape: dict
    compatible: bool
    reason: str = ""


def plan_rescale(cfg, old_mesh_shape: dict, new_mesh_shape: dict,
                 layout: str) -> RescalePlan:
    """Validate a mesh change (divisibility constraints per layout)."""
    G_new = new_mesh_shape.get("model", 1)
    ok, why = True, ""
    if cfg.num_heads and cfg.num_heads % G_new and G_new % cfg.num_heads:
        ok, why = False, f"heads {cfg.num_heads} !~ model axis {G_new}"
    if cfg.is_moe:
        import math
        if math.gcd(cfg.num_experts, G_new) == 0:
            ok, why = False, "expert divisibility"
    return RescalePlan(old_mesh_shape, new_mesh_shape, ok, why)


def elastic_restore(ckpt_path: str, cfg, layout: str, new_mesh, *,
                    model_axis: str = "model"):
    """Restore a checkpoint onto a different mesh (the rescale operation)."""
    from repro.distributed.checkpoint import restore_checkpoint
    G = new_mesh.shape[model_axis]
    return restore_checkpoint(ckpt_path, cfg, layout, G)


# ---------------------------------------------------------------------------
# Serving-side failure recovery
# ---------------------------------------------------------------------------

def fail_rank(engine, data_group: int, rank: int) -> list:
    """Simulate losing model-rank `rank` of `data_group`: every request whose
    KV touches that rank loses its cache and is rescheduled via re-prefill.

    Under EP only the rank's own requests are hit; under TP every request in
    the group holds a head-shard there, so the whole group re-prefills —
    the capacity/blast-radius asymmetry of the two layouts.
    """
    # fused decode: consume in-flight tokens so every request sits at a
    # step boundary (requeueing mid-flight would leave a live device slot
    # writing KV through a stale block table into released pages)
    engine._drain_decode()
    per_rank = engine.active.kv_per_rank
    hit = []
    for r in list(engine.running.values()) + list(engine.prefilling):
        if r.data_group != data_group:
            continue
        if per_rank and r.owner_rank != rank:
            continue
        hit.append(r)
    # the failed rank's cached prefixes are gone with its HBM: drop the
    # affected pool's index (per-rank pool under EP; whole group when the
    # pooled view sharded every page's heads across the rank)
    if getattr(engine, "prefix", None) is not None:
        engine.prefix[data_group].drop_pool(rank if per_rank else 0)
    for r in hit:
        # release pages (to the recorded pool), teacher-force the generated
        # prefix, vacate the device slot, re-prefill — the engine's shared
        # requeue path (same one preemption uses)
        engine.requeue_for_reprefill(r)
    return hit
