"""Elastic scaling + failure recovery.

Training: re-mesh via the layout-agnostic checkpoint (shrink/grow the data
axis, or change the model-group size where divisibility allows) — restore
reshards automatically because the on-disk form is global-logical.

Serving: a lost rank's KV is host-recoverable metadata + re-prefill: the
affected requests' prompts are extended by their generated tokens (teacher-
forced) and re-enter the prefill queue; no other rank's state is touched.
The TP->EP greedy partitioner doubles as the rebalancing step afterwards.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.layouts import EP
from repro.serving.request import State


@dataclass(frozen=True)
class RescalePlan:
    old_shape: dict
    new_shape: dict
    compatible: bool
    reason: str = ""


def plan_rescale(cfg, old_mesh_shape: dict, new_mesh_shape: dict,
                 layout: str) -> RescalePlan:
    """Validate a mesh change (divisibility constraints per layout)."""
    G_new = new_mesh_shape.get("model", 1)
    ok, why = True, ""
    if cfg.num_heads and cfg.num_heads % G_new and G_new % cfg.num_heads:
        ok, why = False, f"heads {cfg.num_heads} !~ model axis {G_new}"
    if cfg.is_moe:
        import math
        if math.gcd(cfg.num_experts, G_new) == 0:
            ok, why = False, "expert divisibility"
    return RescalePlan(old_mesh_shape, new_mesh_shape, ok, why)


def elastic_restore(ckpt_path: str, cfg, layout: str, new_mesh, *,
                    model_axis: str = "model"):
    """Restore a checkpoint onto a different mesh (the rescale operation)."""
    from repro.distributed.checkpoint import restore_checkpoint
    G = new_mesh.shape[model_axis]
    return restore_checkpoint(ckpt_path, cfg, layout, G)


# ---------------------------------------------------------------------------
# Serving-side failure recovery
# ---------------------------------------------------------------------------

def fail_rank(engine, data_group: int, rank: int) -> list:
    """Simulate losing model-rank `rank` of `data_group`: every request whose
    KV touches that rank loses its cache and is rescheduled via re-prefill.

    Under EP only the rank's own requests are hit; under TP every request in
    the group holds a head-shard there, so the whole group re-prefills —
    the capacity/blast-radius asymmetry of the two layouts.
    """
    hit = []
    for r in list(engine.running.values()) + list(engine.prefilling):
        if r.data_group != data_group:
            continue
        if engine.active == EP and r.owner_rank != rank:
            continue
        hit.append(r)
    for r in hit:
        # release pages, teacher-force the generated prefix, re-prefill
        owner = r.owner_rank if engine.active == EP else 0
        if r.pages:
            engine.alloc[data_group].release(max(owner, 0), r.pages)
            r.pages = []
        r.prompt = list(r.prompt) + list(r.output)
        if r.forced_len is not None:
            r.forced_len = max(1, r.forced_len - len(r.output))
        else:
            r.max_new_tokens = max(1, r.max_new_tokens - len(r.output))
        r.output = []
        r.prefill_pos = 0
        r.state = State.WAITING
        r.owner_rank = 0
        engine.running.pop(r.rid, None)
        if r in engine.prefilling:
            engine.prefilling.remove(r)
        engine.waiting.append(r)
    return hit
