"""Elastic scaling + failure recovery.

Training: re-mesh via the layout-agnostic checkpoint (shrink/grow the data
axis, or change the model-group size where divisibility allows) — restore
reshards automatically because the on-disk form is global-logical.

Serving: a lost rank's KV is host-recoverable metadata + re-prefill: the
affected requests' prompts are extended by their generated tokens (teacher-
forced) and re-enter the prefill queue; no other rank's state is touched.
The TP->EP greedy partitioner doubles as the rebalancing step afterwards.

Rank failure is the degenerate case of a cross-world shrink (DESIGN.md
§13): the blast-radius classification routes through the shared
`core.switch.plan_rank_shrink` planner, the same ownership diff an
elastic world-size switch uses.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.serving.request import State


@dataclass(frozen=True)
class RescalePlan:
    old_shape: dict
    new_shape: dict
    compatible: bool
    reason: str = ""


def plan_rescale(cfg, old_mesh_shape: dict, new_mesh_shape: dict,
                 layout: str) -> RescalePlan:
    """Validate a mesh change (divisibility constraints per layout)."""
    G_new = new_mesh_shape.get("model", 1)
    ok, why = True, ""
    if cfg.num_heads and cfg.num_heads % G_new and G_new % cfg.num_heads:
        ok, why = False, f"heads {cfg.num_heads} !~ model axis {G_new}"
    if cfg.is_moe:
        # experts must tile the model axis in one direction: E % G == 0
        # (each rank owns E/G experts) or G % E == 0 (experts replicated
        # across rank subgroups). gcd(E, G) == 0 only when BOTH are zero,
        # so the old check rejected nothing.
        if cfg.num_experts % G_new and G_new % cfg.num_experts:
            ok, why = False, (f"experts {cfg.num_experts} !~ "
                              f"model axis {G_new}")
    return RescalePlan(old_mesh_shape, new_mesh_shape, ok, why)


def elastic_restore(ckpt_path: str, cfg, layout: str, new_mesh, *,
                    model_axis: str = "model"):
    """Restore a checkpoint onto a different mesh (the rescale operation)."""
    from repro.distributed.checkpoint import restore_checkpoint
    G = new_mesh.shape[model_axis]
    return restore_checkpoint(ckpt_path, cfg, layout, G)


# ---------------------------------------------------------------------------
# Serving-side failure recovery
# ---------------------------------------------------------------------------

def fail_rank(engine, data_group: int, rank: int) -> list:
    """Simulate losing model-rank `rank` of `data_group`: every request whose
    KV touches that rank loses its cache and is rescheduled via re-prefill.

    Under EP only the rank's own requests are hit; under TP every request in
    the group holds a head-shard there, so the whole group re-prefills —
    the capacity/blast-radius asymmetry of the two layouts.

    Legal DURING a chunked switch (DESIGN.md §12): the in-flight session is
    aborted first — its staged buffers and planned dst pages (which may
    target the failed rank) are dropped wholesale — then the recovery runs
    against the still-live source layout. A per-rank (EP) failure also
    marks the rank's page pool dead so placement avoids it until every hit
    request has re-prefilled (degraded-mode serving).
    """
    in_flight = getattr(engine, "switch_in_progress", None)
    if in_flight is not None and in_flight():
        engine.abort_switch(f"rank {rank} of group {data_group} failed "
                            f"mid-switch")
    # fused decode: consume in-flight tokens so every request sits at a
    # step boundary (requeueing mid-flight would leave a live device slot
    # writing KV through a stale block table into released pages)
    engine._drain_decode()
    per_rank = engine.active.kv_per_rank
    # blast radius = the shared cross-world ownership diff's shrink case
    from repro.core.switch import plan_rank_shrink
    hit = plan_rank_shrink(
        list(engine.running.values()) + list(engine.prefilling),
        data_group, rank, per_rank)
    # the failed rank's cached prefixes are gone with its HBM: drop the
    # affected pool's index (per-rank pool under EP; whole group when the
    # pooled view sharded every page's heads across the rank)
    if getattr(engine, "prefix", None) is not None:
        engine.prefix[data_group].drop_pool(rank if per_rank else 0)
    # degraded mode: a per-rank failure takes its pool out of prefill
    # placement until the recovery completes (getattr guards keep older
    # duck-typed engine stand-ins working)
    sched = getattr(engine, "sched", None)
    if per_rank and sched is not None:
        sched.mark_pool_dead(data_group, rank)
    for r in hit:
        # release pages (to the recorded pool), teacher-force the generated
        # prefix, vacate the device slot, re-prefill — the engine's shared
        # requeue path (same one preemption uses)
        engine.requeue_for_reprefill(r)
    note = getattr(engine, "note_rank_failure", None)
    if note is not None:
        note(data_group, rank, hit, per_rank and sched is not None)
    return hit
