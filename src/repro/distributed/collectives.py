"""Collective reshard plans + chunked/pipelined variants (overlap machinery).

`chunked_all_to_all` splits a large reshard into per-layer waves of
`ppermute`s so XLA can overlap wave k+1's sends with wave k's local permute
— the portable analogue of the paper's double-buffered per-layer transfer
(their N+1 spare slot). `estimate_collective_bytes` is the first-principles
model used by the roofline (cross-checked against HLO parsing in
launch/dryrun.py)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.layouts import EP, TP, get_layout, group_info
from repro.models.common import ModelConfig
from repro.models.moe import make_expert_layout


def chunked_all_to_all(x: jax.Array, axis: str, n_chunks: int):
    """all_to_all over dim 0 (size G), split into `n_chunks` waves along
    dim 1 so transfers pipeline with surrounding compute."""
    if n_chunks <= 1:
        return lax.all_to_all(x, axis, split_axis=0, concat_axis=0,
                              tiled=True)
    parts = jnp.split(x, n_chunks, axis=1)
    outs = [lax.all_to_all(p, axis, split_axis=0, concat_axis=0, tiled=True)
            for p in parts]
    return jnp.concatenate(outs, axis=1)


# ---------------------------------------------------------------------------
# First-principles per-step collective bytes (roofline's third term)
# ---------------------------------------------------------------------------

def decode_collective_bytes(cfg: ModelConfig, layout: str, B: int, G: int,
                            bytes_per_el: int = 2) -> int:
    """Per-rank collective payload bytes for ONE decode step."""
    D, L = cfg.d_model, cfg.num_layers
    if get_layout(layout).base is TP:
        # two ring all-reduces of the (B, D) hidden per layer
        per_layer = 2 * 2 * (G - 1) / G * B * D * bytes_per_el
        return int(L * per_layer)
    if cfg.is_moe:
        lay = make_expert_layout(cfg.num_experts, G, EP)
        tok = B / G
        per_layer = 2 * tok * cfg.top_k * lay.tp_inner * D * bytes_per_el \
            * (G - 1) / G
    else:
        tok = B / G
        per_layer = 2 * 2 * (G - 1) / G * tok * D * bytes_per_el
    return int(L * per_layer)


def train_collective_bytes(cfg: ModelConfig, layout: str, tokens_global: int,
                           G: int, dp: int, param_count: int,
                           bytes_per_el: int = 2) -> dict:
    """Per-rank collective bytes for one train step (fwd+bwd TP collectives
    + DP gradient all-reduce)."""
    fwd = decode_collective_bytes(cfg, layout, tokens_global, G, bytes_per_el)
    tp_bytes = 3 * fwd                      # fwd + 2x in bwd (transpose)
    dp_bytes = int(2 * (dp - 1) / dp * param_count / G * 4)  # fp32 grads
    return {"tp_bytes": tp_bytes, "dp_bytes": dp_bytes,
            "total": tp_bytes + dp_bytes}


def switch_bytes(cfg: ModelConfig, G: int, live_tokens: int,
                 bytes_per_el: int = 2) -> dict:
    """Owner-changed bytes of one EP<->TP switch (paper's irreducible cost).

    Experts: each rank keeps 1/G of what it holds; (G-1)/G of the expert
    bytes cross the interconnect. KV: every live token's bytes move once
    (minus the 1/G that stays local)."""
    expert_bytes = (cfg.num_layers * cfg.num_experts
                    * 3 * cfg.d_model * cfg.d_expert * bytes_per_el)
    kv_bytes = (live_tokens * _kv_layers(cfg) * 2
                * cfg.num_kv_heads * cfg.dh * bytes_per_el)
    frac = (G - 1) / G
    return {"expert_bytes_moved": int(expert_bytes * frac),
            "kv_bytes_moved": int(kv_bytes * frac),
            "per_rank_expert": int(expert_bytes * frac / G),
            "per_rank_kv": int(kv_bytes * frac / G)}


def _kv_layers(cfg: ModelConfig) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.attn_every
    return cfg.num_layers
