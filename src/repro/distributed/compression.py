"""Gradient compression for the DP all-reduce: int8 quantization and top-k
sparsification, both with error feedback (residual carried to the next
step so compression error doesn't bias convergence)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import shard_map


def quantize_int8(x: jax.Array):
    """Per-tensor symmetric int8. Returns (q int8, scale fp32)."""
    x = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def topk_sparsify(x: jax.Array, frac: float):
    """Keep the top `frac` fraction by magnitude; returns (values, indices)."""
    flat = x.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.shape[0] * frac))
    vals, idx = lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx.astype(jnp.int32)


def topk_densify(vals, idx, shape):
    out = jnp.zeros(int(jnp.prod(jnp.array(shape))), jnp.float32)
    return out.at[idx].set(vals).reshape(shape)


def compressed_psum_int8(g: jax.Array, axis: str, residual: jax.Array):
    """Inside shard_map over the DP axis: error-feedback int8 all-reduce.

    Each rank quantizes (g + residual), all-gathers the int8 payloads +
    scales (4x less wire traffic than fp32 psum), dequantizes and sums
    locally. Returns (g_reduced, new_residual).
    """
    x = g.astype(jnp.float32) + residual
    q, scale = quantize_int8(x)
    new_residual = x - dequantize_int8(q, scale)
    qs = lax.all_gather(q, axis)                      # (G, ...)
    ss = lax.all_gather(scale, axis)                  # (G,)
    summed = jnp.tensordot(ss, qs.astype(jnp.float32), axes=([0], [0]))
    return summed, new_residual


def make_compressed_allreduce(mesh, axis: str = "data"):
    """jit(shard_map) wrapper: grads sharded over `axis` -> mean-reduced."""
    from jax.sharding import PartitionSpec as P

    @partial(shard_map, mesh=mesh,
             in_specs=(P(axis), P(axis)), out_specs=(P(axis), P(axis)))
    def fn(g, res):
        # g: this rank's microbatch grad (leading dummy shard dim of 1)
        out, new_res = compressed_psum_int8(g[0], axis, res[0])
        G = mesh.shape[axis]
        return (out / G)[None], new_res[None]

    return jax.jit(fn)
