"""Stdlib-only HTTP/SSE frontend over the AsyncEngine (DESIGN.md §11).

One asyncio event loop, no threads, no third-party deps: every request
handler *cooperatively pumps* the synchronous AsyncEngine — one engine
iteration per pump, `await asyncio.sleep(0)` in between — so any number
of concurrent HTTP streams interleave over the SAME continuous batch,
exactly like in-process `TokenStream`s. The engine sequence is identical
to batch mode, so SSE-streamed tokens are byte-for-byte the batch
`generate()` outputs, across live layout switches included
(tests/test_http.py).

Endpoints:

  POST /v1/generate
      body: {"prompt": [token ids], "max_new_tokens": int,
             "slo_class": "interactive" | "batch" (default interactive),
             "stream": bool (default true),
             "max_time": seconds | null (per-request deadline: past it
             the request finishes truncated with whatever it generated)}
      stream=true  -> text/event-stream; one `data: {"token": id}` event
                      per generated token, then `data: [DONE]`. A client
                      that disconnects mid-stream gets its request
                      CANCELLED: the engine frees the slot/pages through
                      the normal finish path and other streams continue
                      (`client_disconnects` in /v1/metrics).
      stream=false -> application/json {"rid", "tokens", "n"}.

  GET /v1/metrics
      ServeMetrics.summary() as JSON — flat keys plus the per-class
      `by_class` breakdown (attainment, per-class p50/p99).

  GET /v1/layouts
      MoebiusEngine.layouts_summary() as JSON: the resident layouts with
      their worlds (device counts), the active layout, degraded (dead)
      pools, and switch/backoff state — the observability surface of
      elastic world-size switching (DESIGN.md §13).

Run it standalone via `python -m repro.launch.serve --http-port 8000`;
quickstart curl lines are in the README.
"""
from __future__ import annotations

import asyncio
import json


def _sse(obj) -> bytes:
    data = obj if isinstance(obj, str) else json.dumps(obj)
    return f"data: {data}\n\n".encode()


class HttpFrontend:
    """Minimal HTTP/1.1 server bridging sockets to one AsyncEngine."""

    def __init__(self, frontend, host: str = "127.0.0.1", port: int = 0):
        self.fe = frontend
        self.host = host
        self.port = port                   # 0 = pick a free port
        self._server = None

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    async def start(self) -> "HttpFrontend":
        self._server = await asyncio.start_server(self._handle, self.host,
                                                  self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self

    async def close(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    async def serve_forever(self) -> None:
        async with self._server:
            await self._server.serve_forever()

    # ------------------------------------------------------------------
    # request plumbing
    # ------------------------------------------------------------------
    async def _read_request(self, reader):
        """Parse one HTTP/1.1 request head + Content-Length body."""
        line = await reader.readline()
        if not line:
            return None
        try:
            method, path, _ = line.decode().split(None, 2)
        except ValueError:
            return None
        headers = {}
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            k, _, v = h.decode().partition(":")
            headers[k.strip().lower()] = v.strip()
        n = int(headers.get("content-length", 0) or 0)
        body = await reader.readexactly(n) if n else b""
        return method.upper(), path, headers, body

    @staticmethod
    def _head(status: str, ctype: str, extra: str = "") -> bytes:
        return (f"HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\n"
                f"Cache-Control: no-cache\r\nConnection: close\r\n"
                f"{extra}\r\n").encode()

    async def _json(self, writer, obj, status: str = "200 OK") -> None:
        body = json.dumps(obj).encode()
        writer.write(self._head(status, "application/json",
                                f"Content-Length: {len(body)}\r\n"))
        writer.write(body)
        await writer.drain()

    async def _handle(self, reader, writer) -> None:
        try:
            req = await self._read_request(reader)
            if req is None:
                return
            method, path, _, body = req
            if method == "POST" and path == "/v1/generate":
                await self._generate(writer, body)
            elif method == "GET" and path == "/v1/metrics":
                await self._json(writer, self.fe.metrics.summary())
            elif method == "GET" and path == "/v1/layouts":
                await self._json(writer, self.fe.engine.layouts_summary())
            else:
                await self._json(writer, {"error": f"no route {method} "
                                                   f"{path}"},
                                 "404 Not Found")
        except (ConnectionError, asyncio.IncompleteReadError):
            pass                           # client went away mid-stream
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except ConnectionError:
                pass

    # ------------------------------------------------------------------
    # /v1/generate
    # ------------------------------------------------------------------
    async def _generate(self, writer, body: bytes) -> None:
        try:
            spec = json.loads(body or b"{}")
            prompt = [int(x) for x in spec["prompt"]]
        except (json.JSONDecodeError, KeyError, TypeError, ValueError) as e:
            await self._json(writer, {"error": f"bad request: {e!r}"},
                             "400 Bad Request")
            return
        max_time = spec.get("max_time")
        stream = self.fe.generate(
            prompt,
            max_new_tokens=int(spec.get("max_new_tokens", 16)),
            slo_class=str(spec.get("slo_class", "interactive")),
            max_time=float(max_time) if max_time is not None else None)
        if spec.get("stream", True):
            await self._stream_sse(writer, stream)
        else:
            toks = await self._drive(stream)
            await self._json(writer, {"rid": stream.rid, "tokens": toks,
                                      "n": len(toks)})

    async def _drive(self, stream) -> list:
        """Pump the shared engine loop until `stream` finishes, yielding
        to other handlers between iterations; returns all its tokens.
        Another handler's pump may finish this stream for us — only pump
        while the engine still has work."""
        toks = list(stream.drain_available())
        while not stream.finished:
            if self.fe.engine.sched.has_work():
                self.fe._pump()
            toks.extend(stream.drain_available())
            await asyncio.sleep(0)
        toks.extend(stream.drain_available())
        return toks

    async def _stream_sse(self, writer, stream) -> None:
        """Stream one request's tokens; a broken pipe mid-stream cancels
        the request (DESIGN.md §12) so its slot and pages go back to the
        batch instead of decoding for a client that is gone."""
        writer.write(self._head("200 OK", "text/event-stream"))
        try:
            await writer.drain()
            while True:
                # drain first, test finished after: a finished request
                # can't grow its output, so empty-after-drain + finished
                # == done
                for tok in stream.drain_available():
                    writer.write(_sse({"token": int(tok)}))
                await writer.drain()
                if stream.finished:
                    break
                if writer.transport.is_closing():
                    raise ConnectionResetError("client went away")
                if self.fe.engine.sched.has_work():
                    self.fe._pump()
                await asyncio.sleep(0)
            writer.write(_sse("[DONE]"))
            await writer.drain()
        except (ConnectionError, OSError):
            # handled here (not in _handle's net) so the cancel happens
            # even for non-Connection OSErrors; the writer closes in
            # _handle's finally either way
            if not stream.finished:
                self.fe.cancel(stream.rid)


async def serve_http(frontend, host: str = "127.0.0.1",
                     port: int = 8000) -> None:
    """Blocking entrypoint for `repro.launch.serve --http-port`."""
    srv = await HttpFrontend(frontend, host, port).start()
    print(f"serving on http://{srv.host}:{srv.port} "
          f"(POST /v1/generate, GET /v1/metrics, GET /v1/layouts)",
          flush=True)
    await srv.serve_forever()
