"""Serving launcher: run the Moebius engine on a workload.

The engine keeps every layout named in ``--layouts`` resident and the
switch policy picks between them: the registered specs are ``tp``, ``ep``,
and the hybrid ``tpep`` (TP attention + experts over the full mesh). With
more than two layouts the coordinator scores candidates with the analytical
cost model (KV-feasibility included) behind the paper's hysteresis band.

The run is driven through the AsyncEngine streaming frontend (DESIGN.md
§7): the trace is submitted as per-request token streams, the idle
fast-forward jumps quiet periods, and the summary reports per-request
TTFT/TPOT p50/p99 from ServeMetrics.

Examples (CPU, 8 host devices):
  REPRO_HOST_DEVICES=8 PYTHONPATH=src python -m repro.launch.serve \
      --workload rollout --scale 0.02 --mesh 1x4 --policy rollout
  REPRO_HOST_DEVICES=8 PYTHONPATH=src python -m repro.launch.serve \
      --workload bursty --scale 0.05 --mesh 2x4
  # three-layout runtime: tpep is a reachable operating point
  REPRO_HOST_DEVICES=8 PYTHONPATH=src python -m repro.launch.serve \
      --workload bursty --scale 0.05 --mesh 2x4 --layouts tp,ep,tpep
  # serve statically on the hybrid layout
  REPRO_HOST_DEVICES=8 PYTHONPATH=src python -m repro.launch.serve \
      --workload rollout --scale 0.02 --mesh 2x4 --policy static-tpep \
      --layouts tp,ep,tpep
  # elastic world sizes (DESIGN.md §13): tp@2 is a 2-device operating
  # point — the policy shrinks 4->2 when quiet, grows back on bursts
  REPRO_HOST_DEVICES=8 PYTHONPATH=src python -m repro.launch.serve \
      --workload bursty --scale 0.05 --mesh 2x4 --layouts tp,ep,tp@2
  # multi-tenant QoS trace (DESIGN.md §11), 30% tagged interactive
  REPRO_HOST_DEVICES=8 PYTHONPATH=src python -m repro.launch.serve \
      --workload bursty --scale 0.05 --mesh 1x4 --slo-class-mix 0.3
  # HTTP/SSE frontend (POST /v1/generate, GET /v1/metrics)
  REPRO_HOST_DEVICES=4 PYTHONPATH=src python -m repro.launch.serve \
      --mesh 1x4 --http-port 8000
"""
import os
if "REPRO_HOST_DEVICES" in os.environ:
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_HOST_DEVICES"])


def main():
    import argparse
    import json

    import jax

    from repro.configs import get_config
    from repro.core.layouts import EP, TP, get_layout
    from repro.core.policy import PolicyConfig, calibrate_threshold
    from repro.launch.mesh import make_mesh
    from repro.serving.engine import EngineConfig, MoebiusEngine
    from repro.serving.frontend import AsyncEngine
    from repro.serving.kvcache import CacheConfig
    from repro.serving.workloads import (BurstySpec, QosMixSpec, RolloutSpec,
                                         bursty_trace, qos_mixed_trace,
                                         replay, rollout_batch)

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--mesh", default="1x4")
    ap.add_argument("--workload", default="rollout",
                    choices=["rollout", "bursty", "qosmix"])
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--layouts", default="tp,ep",
                    help="comma-separated registered layouts the engine "
                         "keeps resident (e.g. tp,ep,tpep). A name may "
                         "carry a device count: tp@8,ep@8,tp@4 makes the "
                         "4-device tp a reachable operating point, so the "
                         "policy can shrink the serving world when the "
                         "queue is quiet and grow it back under bursts "
                         "(DESIGN.md §13)")
    ap.add_argument("--policy", default="interactive",
                    choices=["interactive", "rollout", "static-tp",
                             "static-ep", "static-tpep"])
    ap.add_argument("--t-high", type=int, default=None)
    ap.add_argument("--decode-steps", type=int, default=1,
                    help="fuse N decode steps under one dispatch (device-"
                         "resident decode state; N=1 is the classic "
                         "per-token host loop)")
    ap.add_argument("--prefill-chunk", type=int, default=64,
                    help="prefill chunk width in tokens (rounded up to a "
                         "multiple of every resident layout's "
                         "prefill_quantum)")
    ap.add_argument("--token-budget", type=int, default=0,
                    help="per-iteration mixed-batch token budget (decode "
                         "tokens first, prefill chunks into the remainder); "
                         "0 = auto: the quantum-rounded prefill chunk, so "
                         "full-mesh layouts keep their 1/G-per-rank split")
    ap.add_argument("--two-phase", action="store_true",
                    help="legacy separate prefill/decode dispatches per "
                         "iteration instead of one mixed-batch step "
                         "(byte-identical outputs; two dispatches/iter)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable shared-prefix page reuse (refcounted "
                         "pages + CoW; on by default)")
    ap.add_argument("--samples-per-prompt", type=int, default=1,
                    help="rollout workload: completions sampled per "
                         "distinct prompt (shared-prefix groups)")
    ap.add_argument("--qos", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="class-aware QoS scheduling + interactive-"
                         "attainment switch gating (DESIGN.md §11); "
                         "--no-qos serves class-blind")
    ap.add_argument("--slo-class-mix", type=float, default=0.0,
                    help="fraction of trace requests tagged 'interactive' "
                         "(rest 'batch'; deterministic in --seed). 0 "
                         "keeps the workload's own tags")
    ap.add_argument("--http-port", type=int, default=None,
                    help="serve the HTTP/SSE frontend on this port "
                         "instead of replaying a trace (POST /v1/generate"
                         ", GET /v1/metrics; 0 = pick a free port)")
    ap.add_argument("--attn-backend", default=None,
                    choices=["ref", "kernel", "pallas", "interpret"],
                    help="paged-attention backend (default: auto — kernel "
                         "on TPU, ref elsewhere)")
    ap.add_argument("--moe-backend", default=None,
                    choices=["ref", "kernel", "pallas", "interpret"],
                    help="grouped MoE GEMM backend (same auto policy)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-steps", type=int, default=5000)
    args = ap.parse_args()

    dd, g = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((dd, g), ("data", "model"))
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    layouts = tuple(get_layout(l.strip())
                    for l in args.layouts.split(",") if l.strip())
    th = args.t_high or max(8, calibrate_threshold(cfg, g))
    if args.policy == "interactive":
        pol = PolicyConfig.interactive(th)
        start = TP
    elif args.policy == "rollout":
        pol = PolicyConfig.rollout(th)
        start = EP
    else:
        pol = PolicyConfig(t_high=10**9, t_low=-1, cooldown_s=10**9)
        start = get_layout(args.policy.removeprefix("static-"))
    cc = CacheConfig(page_size=16, pages_ep=256, max_pages_per_req=64)
    eng = MoebiusEngine(cfg, mesh, cc,
                        ecfg=EngineConfig(start_layout=start,
                                          layouts=layouts,
                                          ladder=(g, 4 * g, 16 * g),
                                          prefill_chunk=args.prefill_chunk,
                                          token_budget=args.token_budget,
                                          mixed_batch=not args.two_phase,
                                          policy=pol,
                                          decode_steps=args.decode_steps,
                                          prefix_cache=not args.no_prefix_cache,
                                          qos=args.qos,
                                          attn_backend=args.attn_backend,
                                          moe_backend=args.moe_backend,
                                          seed=args.seed))
    if args.http_port is not None:
        # live HTTP/SSE mode: no trace — requests arrive over the wire
        import asyncio

        from repro.launch.http import serve_http
        eng.warmup()
        asyncio.run(serve_http(AsyncEngine(eng), port=args.http_port))
        return
    if args.workload == "rollout":
        reqs = rollout_batch(
            RolloutSpec(scale=args.scale,
                        samples_per_prompt=args.samples_per_prompt),
            seed=args.seed)
    elif args.workload == "qosmix":
        reqs = qos_mixed_trace(QosMixSpec(), seed=args.seed)
    else:
        reqs = bursty_trace(BurstySpec(scale=args.scale), seed=args.seed)
    if args.slo_class_mix > 0:
        import numpy as np
        mix_rng = np.random.default_rng(args.seed + 1)
        for r in reqs:
            r.slo_class = ("interactive"
                           if mix_rng.random() < args.slo_class_mix
                           else "batch")
    fe = AsyncEngine(eng)
    streams = replay(fe, reqs)
    summary = eng.run(max_steps=args.max_steps)
    summary["streams_finished"] = sum(s.finished for s in streams.values())
    summary["switches"] = len(eng.switch_records)
    summary["final_layout"] = eng.active
    summary["layouts"] = [str(l) for l in eng.layouts]
    print(json.dumps(summary, indent=1))


if __name__ == "__main__":
    main()
