import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x shape x mesh x layout)
# cell on placeholder devices; record memory analysis, cost analysis, HLO
# collective counts, and analytic roofline terms.
#
# Run:  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
#           --shape decode_32k --mesh pod1 --layout ep
#       PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh pod1|pod2]
# Results land in results/dryrun/<arch>__<shape>__<mesh>__<layout>.json.
# NOTE: the XLA_FLAGS line above MUST stay the first statement — jax locks
# the device count at first init (so no `from __future__` here).
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPES, ShapeSpec, cell_applicable
from repro.core.layouts import EP, TP, TPEP, expand_kv_heads, group_info
from repro.launch.mesh import data_axes_of, make_production_mesh
from repro.models.common import ModelConfig
from repro.serving.kvcache import CacheConfig

RESULTS = Path(os.environ.get("REPRO_RESULTS", "results/dryrun"))

# roofline hardware constants (TPU v5e)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_COLL_RE = re.compile(
    r"(all-reduce|all-gather|all-to-all|reduce-scatter|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*\(")
_SHAPE_RE = re.compile(r"^\s*%?\S+\s*=\s*\(?([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}


def parse_hlo_collectives(hlo: str) -> dict:
    """Count collective ops + sum their result bytes from HLO text. Ops in
    while bodies appear once; the analytic model (scan-aware) is primary."""
    counts: dict[str, int] = {}
    bytes_: dict[str, int] = {}
    for line in hlo.splitlines():
        mm = _COLL_RE.search(line)
        if not mm:
            continue
        kind = mm.group(1)
        counts[kind] = counts.get(kind, 0) + 1
        sm = _SHAPE_RE.match(line)
        if sm and sm.group(1) in _DTYPE_BYTES:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            b = _DTYPE_BYTES[sm.group(1)] * int(np.prod(dims)) if dims else 0
            bytes_[kind] = bytes_.get(kind, 0) + b
    return {"counts": counts, "result_bytes": bytes_}


def cc_for(cfg: ModelConfig, G: int, layout: str, group_batch: int,
           seq: int, page: int = 128) -> CacheConfig:
    """Size the unified buffer so `layout` holds group_batch requests of
    `seq` tokens (+1 decode token)."""
    gi = group_info(cfg, G)
    tokens = group_batch * (seq + page)
    if layout == EP:
        per_rank = -(-group_batch // G) * (seq + page)
        pages_ep = per_rank // page + 2
    else:
        pages_tp = tokens // page + 2
        pages_ep = -(-pages_tp * gi.kv_local // cfg.num_kv_heads)
        pages_ep = max(pages_ep, 2)
        # keep the view shapes consistent: pages_tp = pages_ep*K//Kl >= need
        while (pages_ep * cfg.num_kv_heads) // gi.kv_local < pages_tp:
            pages_ep += 1
    maxp = seq // page + 2
    return CacheConfig(page_size=page, pages_ep=pages_ep,
                       max_pages_per_req=maxp)


# ---------------------------------------------------------------------------
# analytic FLOPs / bytes / collective bytes per cell (scan-aware; primary)
# ---------------------------------------------------------------------------

def _expert_bytes_total(cfg: ModelConfig) -> int:
    if not cfg.is_moe:
        return 0
    return cfg.num_layers * cfg.num_experts * 3 * cfg.d_model * cfg.d_expert * 2


def _expected_activated(E: int, k: int, tokens: float) -> float:
    if E == 0 or tokens <= 0:
        return 0.0
    return E * (1.0 - (1.0 - min(k, E) / E) ** max(tokens, 0.0))


def analytic_terms(cfg: ModelConfig, shape: ShapeSpec, layout: str,
                   mesh) -> dict:
    """Per-device per-step roofline terms in seconds (scan-aware, primary).

    compute  = FLOPs_dev / peak ;  memory = HBM bytes_dev / bw ;
    collective = payload bytes_dev / link bw.
    """
    from repro.distributed.collectives import (decode_collective_bytes,
                                               train_collective_bytes)
    from repro.models.registry import count_params_analytic
    G = mesh.shape["model"]
    chips = int(np.prod(list(mesh.shape.values())))
    dp = chips // G
    gi = group_info(cfg, G)
    N = count_params_analytic(cfg)
    Na = count_params_analytic(cfg, active_only=True)
    expert_b = _expert_bytes_total(cfg)              # bf16 bytes, all experts
    nonexpert_b = N * 2 - expert_b
    B, S = shape.global_batch, shape.seq_len
    Lk = _kv_layers(cfg)
    kv_tok_bytes = 2 * cfg.num_kv_heads * cfg.dh * 2 * Lk   # K+V, bf16
    window = cfg.sliding_window or 0
    ctx = min(S, window) if window else S

    if shape.kind == "train":
        tokens = B * S
        model_flops = 6 * Na * tokens
        if cfg.num_heads:
            model_flops += 3 * 2 * tokens * (min(S, window or S) / 2) \
                * cfg.num_heads * cfg.dh * 2
        flops_dev = model_flops / chips
        # fwd reads + bwd reads + grad writes of the local shard; activations
        bytes_dev = 3 * (N * 2) / G \
            + 8 * (tokens / dp) * cfg.d_model * 2 * cfg.num_layers / 1
        coll_bytes = train_collective_bytes(
            cfg, layout, tokens // dp, G, dp, N)["total"]
        useful = 6 * Na * tokens / chips
    elif shape.kind == "prefill":
        q_tokens = B * S
        model_flops = 2 * Na * q_tokens
        if cfg.num_heads:
            model_flops += 2 * q_tokens * (ctx / 2) * cfg.num_heads \
                * cfg.dh * 2
        flops_dev = model_flops / chips
        # weights once + activations + KV writes
        bytes_dev = (N * 2) / G + 4 * (q_tokens / dp) * cfg.d_model * 2 \
            + (q_tokens / chips) * kv_tok_bytes
        coll_bytes = decode_collective_bytes(
            cfg, layout, max(1, B // dp) * S, G)
        useful = 2 * Na * q_tokens / chips
    else:  # decode
        q_tokens = B
        model_flops = 2 * Na * q_tokens
        if cfg.num_heads:
            model_flops += 2 * q_tokens * ctx * cfg.num_heads * cfg.dh * 2
        if cfg.ssm_state:
            model_flops += 2 * q_tokens * cfg.num_layers * cfg.ssm_heads \
                * cfg.ssm_head_dim * cfg.ssm_state * 3
        flops_dev = model_flops / chips
        group_B = max(1, B // dp)
        if layout == TPEP:
            # TP attention + experts over the full mesh (G_exp = chips)
            from repro.models.moe import make_expert_layout
            lay = make_expert_layout(cfg.num_experts or 1, chips, EP)
            E_loc = max(1, (cfg.num_experts or 1) // lay.ep)
            routed = B * cfg.top_k / max(lay.ep, 1)
            act = _expected_activated(E_loc, cfg.top_k, routed)
            w_dev = nonexpert_b / G + (act / max(E_loc, 1)) \
                * (expert_b / chips)
            kv_dev = group_B * ctx * gi.kv_local * cfg.dh * 2 * 2 * Lk
        elif layout == TP:
            act = _expected_activated(cfg.num_experts, cfg.top_k, group_B) \
                if cfg.is_moe else 0
            w_dev = nonexpert_b / G + (act / max(cfg.num_experts, 1)) \
                * expert_b / G
            kv_dev = group_B * ctx * gi.kv_local * cfg.dh * 2 * 2 * Lk
        else:
            from repro.models.moe import make_expert_layout
            lay = make_expert_layout(cfg.num_experts or 1, G, EP)
            E_loc = (cfg.num_experts or 1) // lay.ep
            routed = group_B * cfg.top_k / lay.ep if cfg.is_moe else 0
            act = _expected_activated(E_loc, cfg.top_k, routed)
            w_dev = nonexpert_b + (act / max(cfg.num_experts, 1)) \
                * expert_b / lay.tp_inner if cfg.is_moe else nonexpert_b / \
                (G if not cfg.ssm_state else 1)
            if not cfg.is_moe and not cfg.ssm_state:
                # dense DP-attn: attention stack replicated, MLP sharded
                attn_b = cfg.num_layers * (cfg.d_model * cfg.num_heads
                                           * cfg.dh * 2 + 2 * cfg.d_model
                                           * cfg.num_kv_heads * cfg.dh) * 2
                mlp_b = N * 2 - attn_b
                w_dev = attn_b + mlp_b / G
            kv_dev = (group_B / G) * ctx * cfg.num_kv_heads * cfg.dh \
                * 2 * 2 * Lk
        if cfg.ssm_state:
            ssm_b = (group_B / (G if layout == EP else 1)) * cfg.num_layers \
                * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
            kv_dev += ssm_b
        bytes_dev = w_dev + kv_dev + 4 * group_B * cfg.d_model * 2
        if layout == TPEP:
            # attn all-reduce + full-mesh dispatch a2a + model all-gather
            bpe = 2
            per_layer = (2 * (G - 1) / G * group_B * cfg.d_model * bpe
                         + 2 * (group_B / G) * cfg.top_k * cfg.d_model * bpe
                         + (G - 1) / G * group_B * cfg.d_model * bpe)
            coll_bytes = cfg.num_layers * per_layer
        else:
            coll_bytes = decode_collective_bytes(cfg, layout, group_B, G)
        useful = 2 * Na * q_tokens / chips

    return {
        "chips": chips,
        "model_flops_total": float(model_flops),
        "flops_per_dev": float(flops_dev),
        "bytes_per_dev": float(bytes_dev),
        "coll_bytes_per_dev": float(coll_bytes),
        "t_compute": float(flops_dev / PEAK_FLOPS),
        "t_memory": float(bytes_dev / HBM_BW),
        "t_collective": float(coll_bytes / LINK_BW),
        "useful_flops_per_dev": float(useful),
    }


def _kv_layers(cfg: ModelConfig) -> int:
    from repro.serving.kvcache import num_kv_layers
    return num_kv_layers(cfg)


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

def sds(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh, layout: str,
                cc: CacheConfig | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        out = {"tokens": sds((B, S)), "labels": sds((B, S))}
        if cfg.family == "encdec":
            out["frames"] = sds((B, cfg.encoder_seq, cfg.d_model),
                                cfg.compute_dtype)
        if cfg.family == "vlm":
            out["patches"] = sds((B, cfg.num_patches, cfg.d_model),
                                 cfg.compute_dtype)
        return out
    raise ValueError("serve cells build their own specs")


def lower_cell(arch: str, shape_name: str, mesh_kind: str, layout: str,
               *, compile_: bool = True, remat: bool = True,
               grad_accum: int = 1, zero: bool = False,
               page: int = 128) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return {"status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "pod2"))
    da = data_axes_of(mesh)
    G = mesh.shape["model"]
    dp = int(np.prod([mesh.shape[a] for a in da]))
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                 "layout": layout, "devices": int(np.prod(list(mesh.shape.values())))}
    t0 = time.perf_counter()

    if shape.kind == "train":
        from repro.training.train_loop import build_train_step
        step, init_fn, (psh, osh, bsh) = build_train_step(
            cfg, mesh, layout, data_axes=da, grad_accum=grad_accum,
            donate=False, global_batch=shape.global_batch, remat=remat,
            zero=zero)
        pshape, oshape = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
        batch = input_specs(cfg, shape, mesh, layout)
        lowered = step.lower(pshape, oshape, batch)
    else:
        lowered = _lower_serve(cfg, shape, mesh, layout, da, G, dp,
                               page=page)

    rec["lower_s"] = time.perf_counter() - t0
    if compile_:
        t1 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = time.perf_counter() - t1
        ca = compiled.cost_analysis() or {}
        rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                if isinstance(v, (int, float))
                                and k in ("flops", "bytes accessed",
                                          "transcendentals", "utilization")}
        ma = compiled.memory_analysis()
        if ma is not None:
            rec["memory"] = {
                k: int(getattr(ma, k)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(ma, k)}
        rec["hlo_collectives"] = parse_hlo_collectives(compiled.as_text())
    rec["analytic"] = analytic_terms(cfg, shape, layout, mesh)
    rec["status"] = "ok"
    return rec


def _lower_serve(cfg, shape, mesh, layout, da, G, dp, page=128):
    """Lower a serve cell (prefill or decode)."""
    B, S = shape.global_batch, shape.seq_len
    Dd = dp
    group_B = max(1, B // dp)
    if cfg.family == "encdec":
        cfg = cfg.replace(max_positions=max(4096, S + 8))

    if shape.kind == "prefill":
        if cfg.family in ("ssm", "hybrid", "encdec", "vlm"):
            # GSPMD full-sequence forward (prefill compute; see DESIGN.md)
            from repro.core.layouts import (batch_specs, pack_params,
                                            param_specs)
            from repro.models.registry import forward, init_params
            from repro.models.moe import make_expert_layout
            from jax.sharding import NamedSharding
            lay = (make_expert_layout(cfg.num_experts, G, layout)
                   if cfg.is_moe else None)
            pshape = jax.eval_shape(lambda: pack_params(
                cfg, init_params(cfg, jax.random.PRNGKey(0)), layout, G))
            psh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                param_specs(cfg, pshape, layout))
            bspec = batch_specs(layout, da)
            # fall back to DP-only batch sharding when B !% (dp * G)
            ent = bspec[0] if len(bspec) else None
            ent = (ent,) if isinstance(ent, str) else ent
            nshard = int(np.prod([mesh.shape[a]
                                  for ax in ent for a in
                                  ((ax,) if isinstance(ax, str) else ax)])) \
                if ent else 1
            if B % nshard:
                from jax.sharding import PartitionSpec as PS
                bspec = PS(tuple(da), None)
            batch = {"tokens": sds((B, S))}
            bsh = {"tokens": NamedSharding(mesh, bspec)}
            if cfg.family == "encdec":
                batch["frames"] = sds((B, cfg.encoder_seq, cfg.d_model),
                                      cfg.compute_dtype)
                bsh["frames"] = NamedSharding(
                    mesh, jax.sharding.PartitionSpec(bspec[0], None, None))
            if cfg.family == "vlm":
                batch["patches"] = sds((B, cfg.num_patches, cfg.d_model),
                                       cfg.compute_dtype)
                bsh["patches"] = NamedSharding(
                    mesh, jax.sharding.PartitionSpec(bspec[0], None, None))
            fn = jax.jit(lambda p, b: forward(cfg, p, b, lay=lay),
                         in_shardings=(psh, bsh))
            return fn.lower(pshape, batch)
        # transformer families: true paged prefill step
        from repro.serving.steps import build_serve_step, build_decode_pack
        from repro.core.layouts import pack_params
        cc = cc_for(cfg, G, layout, group_B, S, page)
        Bp = group_B if layout == TP else max(G, -(-group_B // G) * G)
        step = build_serve_step(cfg, mesh, layout, cc, Bp, Sq=S,
                                data_axes=da, attn_backend="ref",
                                donate=False)
        return _lower_step(cfg, step, mesh, layout, cc, Bp, S, Dd, G)

    # decode cells
    window = cfg.sliding_window or 0
    eff_S = min(S, window) if window else S
    cc = (cc_for(cfg, G, TP if layout == TPEP else layout, group_B, eff_S,
                 page) if cfg.family != "ssm" else None)
    Bslot = group_B if layout != EP else max(G, -(-group_B // G) * G)
    if layout == TPEP:
        Bslot = max(G, -(-Bslot // G) * G)   # token slice needs G | Bslot
    if cfg.family == "ssm":
        from repro.serving.steps_extra import (build_ssm_serve_step,
                                               ssm_state_shapes)
        step = build_ssm_serve_step(cfg, mesh, layout, Bslot, data_axes=da,
                                    donate=False)
        shp = ssm_state_shapes(cfg, Dd, Bslot)
        dt = cfg.param_dtype
        args = (_ssm_pack_sds(cfg), sds(shp["conv_x"], dt),
                sds(shp["conv_B"], dt), sds(shp["conv_C"], dt),
                sds(shp["ssm"], jnp.float32), sds((Dd, Bslot, 1)),
                sds((Dd, Bslot)), sds((2,), jnp.uint32))
        return step.lower(*args)
    if cfg.family == "hybrid":
        from repro.serving.steps_extra import (build_hybrid_serve_step,
                                               hybrid_decode_pack,
                                               ssm_state_shapes)
        from repro.models.registry import init_params
        from repro.core.layouts import pack_params
        step = build_hybrid_serve_step(cfg, mesh, layout, cc, Bslot,
                                       data_axes=da, attn_backend="ref",
                                       donate=False)
        pk = jax.eval_shape(lambda: hybrid_decode_pack(
            cfg, pack_params(cfg, init_params(cfg, jax.random.PRNGKey(0)),
                             layout, G), layout, G))
        shp = ssm_state_shapes(cfg, Dd, Bslot)
        dt = cfg.param_dtype
        NE = cc.nelems(cfg, G)
        maxp = cc.max_pages_per_req
        args = (pk, sds((Dd, G, NE), dt), sds(shp["conv_x"], dt),
                sds(shp["conv_B"], dt), sds(shp["conv_C"], dt),
                sds(shp["ssm"], jnp.float32), sds((Dd, Bslot, 1)),
                sds((Dd, Bslot)), sds((Dd, Bslot)),
                sds((Dd, Bslot, maxp)), sds((2,), jnp.uint32))
        return step.lower(*args)
    if cfg.family == "encdec":
        from repro.serving.steps_extra import (build_encdec_serve_step,
                                               encdec_decode_pack)
        from repro.models.registry import init_params
        from repro.core.layouts import pack_params, group_info
        gi = group_info(cfg, G)
        step = build_encdec_serve_step(cfg, mesh, layout, cc, Bslot,
                                       cfg.encoder_seq, data_axes=da,
                                       attn_backend="ref", donate=False)
        pk = jax.eval_shape(lambda: encdec_decode_pack(
            cfg, pack_params(cfg, init_params(cfg, jax.random.PRNGKey(0)),
                             layout, G), layout, G))
        NE = cc.nelems(cfg, G)
        maxp = cc.max_pages_per_req
        Kx = G * gi.kv_local if layout == TP else cfg.num_kv_heads
        xkv = sds((Dd, Bslot, cfg.num_layers, 2, cfg.encoder_seq, Kx,
                   cfg.dh), cfg.param_dtype)
        args = (pk, sds((Dd, G, NE), cfg.param_dtype), xkv,
                sds((Dd, Bslot, 1)), sds((Dd, Bslot)), sds((Dd, Bslot)),
                sds((Dd, Bslot, maxp)), sds((2,), jnp.uint32))
        return step.lower(*args)
    # dense / moe / vlm text decode
    from repro.serving.steps import build_serve_step
    step = build_serve_step(cfg, mesh, layout, cc, Bslot, Sq=1,
                            data_axes=da, attn_backend="ref", donate=False)
    return _lower_step(cfg, step, mesh, layout, cc, Bslot, 1, Dd, G)


def _lower_step(cfg, step, mesh, layout, cc, Bslot, Sq, Dd, G):
    from repro.serving.steps import build_decode_pack, _params_like
    G_exp = (int(np.prod(list(mesh.shape.values())))
             if layout == TPEP else None)
    pk = jax.eval_shape(lambda p: build_decode_pack(cfg, p, layout, G),
                        _params_like(cfg, layout, G, G_exp))
    NE = cc.nelems(cfg, G)
    maxp = cc.max_pages_per_req
    args = (pk, sds((Dd, G, NE), cfg.param_dtype),
            sds((Dd, Bslot, Sq)), sds((Dd, Bslot)), sds((Dd, Bslot)),
            sds((Dd, Bslot, maxp)), sds((2,), jnp.uint32))
    return step.lower(*args)


def _ssm_pack_sds(cfg):
    from repro.models.ssm_lm import init_ssm_lm
    import jax.random as jr
    p = jax.eval_shape(lambda: init_ssm_lm(cfg, jr.PRNGKey(0)))
    from repro.core.layouts import padded_vocab
    Vp = padded_vocab(cfg.vocab_size)
    p = dict(p)
    p["embed"] = sds((Vp, cfg.d_model), cfg.param_dtype)
    p["lm_head"] = sds((Vp, cfg.d_model), cfg.param_dtype)
    return p


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def run_cell(arch, shape, mesh_kind, layout, out_dir: Path) -> dict:
    name = f"{arch}__{shape}__{mesh_kind}__{layout}"
    out = out_dir / f"{name}.json"
    try:
        rec = lower_cell(arch, shape, mesh_kind, layout)
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec = {"status": "error", "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-3000:]}
    rec.update({"arch": arch, "shape": shape, "mesh": mesh_kind,
                "layout": layout})
    out_dir.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=1))
    status = rec.get("status")
    extra = ""
    if status == "ok" and "memory" in rec:
        extra = f" argbytes={rec['memory'].get('argument_size_in_bytes', 0)/2**30:.2f}GiB" \
            f" compile={rec.get('compile_s', 0):.1f}s"
    print(f"[dryrun] {name}: {status}{extra}", flush=True)
    return rec


def default_layouts(cfg: ModelConfig, shape: ShapeSpec) -> list[str]:
    outs = [TP, EP]
    # MoE serve cells additionally get TPEP (full-mesh expert parallelism —
    # the HBM-feasible layout for >=100B MoE on 16GB chips)
    if cfg.is_moe and shape.kind != "train":
        outs.append(TPEP)
    return outs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2"])
    ap.add_argument("--layout", default=None, choices=[TP, EP, TPEP])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(RESULTS))
    args = ap.parse_args()
    out_dir = Path(args.out)

    if args.all:
        for arch in ARCHS:
            cfg = get_config(arch)
            for sname, sh in SHAPES.items():
                for layout in ([args.layout] if args.layout
                               else default_layouts(cfg, sh)):
                    run_cell(arch, sname, args.mesh, layout, out_dir)
        return
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    for arch in archs:
        for sname in shapes:
            for layout in ([args.layout] if args.layout else [TP, EP]):
                run_cell(arch, sname, args.mesh, layout, out_dir)


if __name__ == "__main__":
    main()
