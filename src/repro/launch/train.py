"""Training launcher with checkpoint/restart (fault-tolerant loop).

Example (CPU, 8 host devices):
  REPRO_HOST_DEVICES=8 PYTHONPATH=src python -m repro.launch.train \
      --arch qwen2-moe-a2.7b --reduced --mesh 2x4 --layout ep --steps 50
"""
import os
if "REPRO_HOST_DEVICES" in os.environ:
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_HOST_DEVICES"])


def main():
    import argparse
    import time

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.distributed.checkpoint import (restore_checkpoint,
                                              save_checkpoint)
    from repro.launch.mesh import make_mesh
    from repro.training.data import MarkovData
    from repro.training.optimizer import AdamWConfig, adamw_init
    from repro.training.train_loop import build_train_step

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-moe-a2.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="2x4")
    ap.add_argument("--layout", default="ep", choices=["tp", "ep"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--zero", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    dims = tuple(int(x) for x in args.mesh.split("x"))
    axes = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
    mesh = make_mesh(dims, axes)
    G = mesh.shape["model"]
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(param_dtype=jnp.float32, compute_dtype=jnp.float32)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 20),
                          total_steps=args.steps)
    da = tuple(a for a in axes if a != "model")
    step_fn, init_fn, (psh, osh, bsh) = build_train_step(
        cfg, mesh, args.layout, opt=opt_cfg, grad_accum=args.grad_accum,
        data_axes=da, zero=args.zero)

    start = 0
    if args.resume and args.ckpt and os.path.exists(
            os.path.join(args.ckpt, "manifest.json")):
        params, _, start = restore_checkpoint(args.ckpt, cfg, args.layout, G,
                                              shardings=psh)
        opt_state = adamw_init(params)   # moments restart (demo scope)
        print(f"resumed from step {start}")
    else:
        params, opt_state = init_fn(jax.random.PRNGKey(0))

    data = MarkovData(cfg.vocab_size, args.seq, args.batch, seed=7)
    t0 = time.perf_counter()
    for i in range(start, args.steps):
        b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        if cfg.family == "encdec":
            b["frames"] = jnp.zeros((args.batch, cfg.encoder_seq,
                                     cfg.d_model), cfg.compute_dtype)
        if cfg.family == "vlm":
            b["patches"] = jnp.zeros((args.batch, cfg.num_patches,
                                      cfg.d_model), cfg.compute_dtype)
        params, opt_state, m = step_fn(params, opt_state, b)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} "
                  f"lr {float(m['lr']):.2e} "
                  f"({(time.perf_counter()-t0):.1f}s)", flush=True)
        if args.ckpt and (i + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt, cfg, params, args.layout, G,
                            step=i + 1, async_save=True)
    if args.ckpt:
        save_checkpoint(args.ckpt, cfg, params, args.layout, G,
                        step=args.steps)
        print(f"checkpoint saved to {args.ckpt}")


if __name__ == "__main__":
    main()
