"""Production mesh builders (functions, not module constants — importing
this module never touches jax device state)."""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False,
                         world: int | None = None):
    """Single pod: (data=16, model=16) = 256 chips. Multi-pod: leading
    pod axis (2, 16, 16) = 512 chips; `pod` is pure DP. `world` overrides
    the model-axis extent (elastic world sizes, DESIGN.md §13)."""
    g = 16 if world is None else int(world)
    shape = (2, 16, g) if multi_pod else (16, g)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return compat.make_mesh(shape, axes)


def submesh(mesh, world: int, model_axis: str = "model"):
    """Sub-mesh over the first `world` ranks of `mesh`'s model axis —
    the slicing every per-world geometry (executor meshes, sized-layout
    step fns) derives from, so a "tp@4" run on an 8-rank launch uses a
    true 4-rank SPMD mesh in-process."""
    import numpy as np
    from jax.sharding import Mesh
    if not 0 < world <= mesh.shape[model_axis]:
        raise ValueError(f"world {world} not in 1..{mesh.shape[model_axis]}")
    ax = mesh.axis_names.index(model_axis)
    dev = mesh.devices.take(np.arange(world), axis=ax)
    return Mesh(dev, mesh.axis_names)


def data_axes_of(mesh) -> tuple:
    """All pure-DP axes (everything except `model`)."""
    return tuple(a for a in mesh.axis_names if a != "model")
