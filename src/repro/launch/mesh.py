"""Production mesh builders (functions, not module constants — importing
this module never touches jax device state)."""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips. Multi-pod: leading
    pod axis (2, 16, 16) = 512 chips; `pod` is pure DP."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return compat.make_mesh(shape, axes)


def data_axes_of(mesh) -> tuple:
    """All pure-DP axes (everything except `model`)."""
    return tuple(a for a in mesh.axis_names if a != "model")
